"""Durability & fault-tolerance plane (DESIGN.md §16).

Three legs:

  * ``ft.wal`` / ``ft.store`` — crash-safe delta WAL + atomic session
    snapshots; together they make a restart a cache hit instead of a
    re-aggregation, with no acked delta lost.
  * ``ft.resilience`` — deadlines, retry with deterministic backoff,
    overload shedding for the serve path.
  * ``ft.chaos`` — deterministic named crash/fault sites driving the
    crash-matrix tests and the CI recovery smoke.

``chaos`` and ``resilience`` are stdlib-only and imported eagerly (the
core executor's fault site must not pull in the session/serve layers);
``wal`` and ``store`` load lazily on first attribute access.
"""

from . import chaos
from .chaos import FaultInjected, SimulatedCrash, crash_point, fault_point
from .resilience import (
    Deadline,
    DeadlineExceeded,
    RetryPolicy,
    ServerOverloaded,
    TransientError,
    retry_call,
)

__all__ = [
    "chaos",
    "crash_point",
    "fault_point",
    "SimulatedCrash",
    "FaultInjected",
    "Deadline",
    "DeadlineExceeded",
    "RetryPolicy",
    "ServerOverloaded",
    "TransientError",
    "retry_call",
    "CorruptWal",
    "DeltaWAL",
    "fsync_dir",
    "RestoreReport",
    "SessionStore",
    "StoreStats",
    "WalStats",
]

_LAZY = {
    "DeltaWAL": "wal",
    "WalStats": "wal",
    "CorruptWal": "wal",
    "fsync_dir": "wal",
    "SessionStore": "store",
    "StoreStats": "store",
    "RestoreReport": "store",
    "wal": "wal",
    "store": "store",
}


def __getattr__(name: str):
    mod_name = _LAZY.get(name)
    if mod_name is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    mod = importlib.import_module(f".{mod_name}", __name__)
    return mod if name == mod_name else getattr(mod, name)
