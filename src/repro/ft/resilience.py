"""Serve-path resilience primitives: deadlines, retry with backoff,
overload shedding (DESIGN.md §16).

Pure stdlib and fully clock-injectable — every time source and sleep is
a parameter, so the deadline/backoff tests run on fake clocks with zero
real waiting, exactly like the rest of the serving plane
(``Session.clock``, DESIGN.md §12).

  * ``Deadline`` — an absolute expiry on an injectable monotonic clock;
    threaded per-request through ``ModelServer``/``Scheduler`` so a
    caller's time budget bounds queue wait + drain + solve together.
  * ``RetryPolicy``/``retry_call`` — exponential backoff with
    deterministic seeded jitter for *transient* failures only
    (``TransientError``); a deterministic bug fails fast, a flaky
    executor dispatch gets ``max_attempts`` tries.
  * ``ServerOverloaded`` — the load-shedding signal: raised instead of
    queueing when the scheduler is in degraded mode or its fit backlog
    is past ``max_pending_fits``. Predicts keep flowing off the
    lock-free snapshot while fits shed.
"""

from __future__ import annotations

import dataclasses
import random
import time
from typing import Callable, Iterator, Optional, Tuple, Type, Union


class TransientError(Exception):
    """Base class for failures worth retrying: the operation may succeed
    on a clean re-run (executor dispatch hiccup, injected fault). Raise
    a plain ``Exception`` for deterministic errors — retrying those only
    triples the latency of the same failure."""


class DeadlineExceeded(TimeoutError):
    """The request's time budget ran out (queue wait included)."""


class ServerOverloaded(RuntimeError):
    """The write plane shed this request (degraded mode or a full fit
    backlog). The caller should back off and retry; predicts against
    the published snapshot remain available throughout."""


class Deadline:
    """An absolute expiry on an injectable monotonic clock."""

    __slots__ = ("budget_s", "expires_at", "clock")

    def __init__(self, budget_s: float,
                 clock: Callable[[], float] = time.monotonic):
        self.budget_s = budget_s
        self.clock = clock
        self.expires_at = clock() + budget_s

    @staticmethod
    def of(budget_s: Optional[float],
           clock: Callable[[], float] = time.monotonic
           ) -> Optional["Deadline"]:
        """``None`` budget -> no deadline (the common case costs one if)."""
        return None if budget_s is None else Deadline(budget_s, clock)

    def remaining(self) -> float:
        return self.expires_at - self.clock()

    @property
    def expired(self) -> bool:
        return self.remaining() <= 0.0

    def check(self, where: str = "") -> None:
        """Raise ``DeadlineExceeded`` if the budget is spent."""
        if self.expired:
            suffix = f" at {where}" if where else ""
            raise DeadlineExceeded(
                f"deadline of {self.budget_s:.3f}s exceeded{suffix}"
            )


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Exponential backoff with deterministic seeded jitter.

    Backoff before attempt k+1 is ``min(base_s * multiplier**k,
    max_backoff_s) * (1 + jitter * u_k)`` with ``u_k`` drawn uniformly
    from [-1, 1] by a ``random.Random(seed)`` — same seed, same delays,
    so retry tests assert exact schedules."""

    max_attempts: int = 3
    base_s: float = 0.05
    multiplier: float = 2.0
    max_backoff_s: float = 2.0
    jitter: float = 0.1
    seed: int = 0

    def backoffs(self) -> Iterator[float]:
        rng = random.Random(self.seed)
        for k in range(self.max_attempts - 1):
            b = min(self.base_s * self.multiplier ** k, self.max_backoff_s)
            yield b * (1.0 + self.jitter * rng.uniform(-1.0, 1.0))


def retry_call(
    fn: Callable[[], object],
    policy: RetryPolicy,
    retryable: Union[Type[BaseException],
                     Tuple[Type[BaseException], ...]] = TransientError,
    deadline: Optional[Deadline] = None,
    sleep: Callable[[float], None] = time.sleep,
    on_retry: Optional[Callable[[int, BaseException, float], None]] = None,
):
    """Call ``fn`` up to ``policy.max_attempts`` times, sleeping the
    policy's backoff between attempts. Only ``retryable`` exceptions are
    retried; anything else (including ``SimulatedCrash``, a
    ``BaseException``) propagates immediately. With a ``deadline``, a
    retry is abandoned — the last transient error re-raised — rather
    than sleeping past the caller's budget."""
    backoffs = policy.backoffs()
    for attempt in range(policy.max_attempts):
        try:
            return fn()
        except retryable as e:
            if attempt + 1 >= policy.max_attempts:
                raise
            delay = next(backoffs)
            if deadline is not None and deadline.remaining() < delay:
                raise
            if on_retry is not None:
                on_retry(attempt + 1, e, delay)
            sleep(delay)
    raise AssertionError("unreachable")  # loop returns or raises
