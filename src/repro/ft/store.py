"""``SessionStore`` — durable session/server state: snapshot + restore
(DESIGN.md §16).

A snapshot persists everything a warm restart needs to skip the
expensive work: the post-delta relation data, dictionaries and active
domains, every compiled bundle's monomial tables (the output of the
factorized aggregate pass — the state AC/DC's whole economics argue for
reusing), the tenant registry with each tenant's latest parameters, and
the WAL applied-position. Restore rebuilds bundles around the persisted
tables — workload/registers/plan are recomputed structurally, but the
aggregate pass itself (the XLA trace + execution that dominates a cold
start) is NOT re-run — then replays the WAL records the snapshot does
not cover back into the refresh queue. ``bench_recovery`` holds the
line: warm restore ≥5× faster than cold re-aggregation.

On-disk layout (one directory per snapshot, atomically renamed):

    state_dir/
      wal/                      ft.wal.DeltaWAL segments
      snap_00000007/
        manifest.json           format, epoch, wal position, bundle and
                                tenant descriptors — written LAST
        db.npz                  rel__<relation>__<attr> columns
        dicts.npz               dictionary-decode tables
        bundle_0.npz            t<i>__vals / t<i>__k__<attr> per monomial
        tenants.npz             p<i>__theta [p<i>__V] per tenant

Write protocol — the tmp→fsync→rename idiom of ``ckpt.checkpoint``,
completed with the parent-directory fsync: write into
``snap_N.tmp/``, fsync every file, fsync the tmp dir, rename to
``snap_N/``, fsync ``state_dir`` — then (and only then) truncate the
WAL's consumed prefix. A crash anywhere leaves either the old snapshot
plus a longer WAL (replay covers the gap) or the new snapshot plus an
untruncated WAL (replay filters on the manifest's watermark); in no
interleaving is an acknowledged delta lost or applied twice.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import time
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.schema import FD
from repro.core.solver import SolverResult
from repro.session import (
    FactorizationMachine,
    FitResult,
    LinearRegression,
    ModelSpec,
    PolynomialRegression,
    Session,
)
from repro.session.bundle import BundleKey, fd_key

from . import chaos
from .wal import DeltaWAL, fsync_dir

_FORMAT = 1

_SPEC_CLASSES = {
    c.__name__: c
    for c in (LinearRegression, PolynomialRegression, FactorizationMachine)
}


def _spec_to_json(spec: ModelSpec) -> dict:
    cls = type(spec).__name__
    if cls not in _SPEC_CLASSES:
        raise ValueError(
            f"cannot persist unknown spec class {cls!r}; register it in "
            "ft.store._SPEC_CLASSES"
        )
    return {"class": cls, **dataclasses.asdict(spec)}


def _spec_from_json(d: dict) -> ModelSpec:
    d = dict(d)
    return _SPEC_CLASSES[d.pop("class")](**d)


def _fds_to_json(fds) -> list:
    return [[f.determinant, list(f.determined)] for f in fds]


def _fds_from_json(rows) -> Tuple[FD, ...]:
    return tuple(FD(det, tuple(dets)) for det, dets in rows)


def _mono_to_json(mono) -> list:
    return [[var, int(power)] for var, power in mono]


def _mono_from_json(rows) -> tuple:
    return tuple((str(var), int(power)) for var, power in rows)


def _write_npz(tmp_path: str, arrays: Dict[str, np.ndarray],
               fsync: bool) -> None:
    """Write one npz into the snapshot's tmp dir (the caller's rename is
    the commit, so writing in place here is safe by construction)."""
    with open(tmp_path, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        if fsync:
            os.fsync(f.fileno())


@dataclasses.dataclass
class StoreStats(obs.StatsBase):
    snapshots: int = 0
    snapshot_seconds_last: float = 0.0
    snapshot_seconds_total: float = 0.0
    restores: int = 0
    restore_seconds_last: float = 0.0
    bundles_saved: int = 0
    bundles_restored: int = 0
    tenants_saved: int = 0
    tenants_restored: int = 0
    wal_records_requeued: int = 0   # replayed into the refresh queue
    snapshots_pruned: int = 0       # retention removals


@dataclasses.dataclass
class RestoreReport:
    snapshot_id: int
    deltas_applied: int
    bundles: int
    tenants: int
    wal_replayed: int
    seconds: float


class SessionStore:
    """Durable state directory for one serving session."""

    def __init__(self, state_dir: str, keep: int = 2, fsync: bool = True,
                 wal_rotate_bytes: int = 4 << 20):
        self.state_dir = state_dir
        self.keep = keep
        self.fsync = fsync
        self.wal_rotate_bytes = wal_rotate_bytes
        self.stats = StoreStats()
        self._wal: Optional[DeltaWAL] = None
        os.makedirs(state_dir, exist_ok=True)

    @property
    def wal(self) -> DeltaWAL:
        if self._wal is None:
            self._wal = DeltaWAL(
                os.path.join(self.state_dir, "wal"),
                rotate_bytes=self.wal_rotate_bytes,
                fsync=self.fsync,
            )
        return self._wal

    def attach(self, server) -> "SessionStore":
        """Wire this store into a ``ModelServer``: deltas are WAL-logged
        before ack, and the metrics snapshot grows a durability plane."""
        server.refresh.wal = self.wal
        server.state_store = self
        return self

    # ------------------------------------------------------------------
    def _snapshot_ids(self) -> List[int]:
        out = []
        for name in os.listdir(self.state_dir):
            if name.startswith("snap_") and not name.endswith(".tmp"):
                manifest = os.path.join(self.state_dir, name, "manifest.json")
                if os.path.exists(manifest):
                    out.append(int(name[5:]))
        return sorted(out)

    def latest(self) -> Optional[int]:
        """Newest committed snapshot id (a ``.tmp`` from a crashed writer
        is never a candidate — the rename is the commit)."""
        ids = self._snapshot_ids()
        return ids[-1] if ids else None

    # ------------------------------------------------------------------
    # snapshot
    # ------------------------------------------------------------------
    def snapshot(self, session: Session, server=None) -> str:
        """Atomically persist the session (and, with ``server``, the
        tenant registry). Must not run concurrently with drains/fits —
        the scheduler's write lock (or any quiescent point) is the
        caller's responsibility."""
        t0 = time.monotonic()
        with obs.span("ft.snapshot"):
            sid = (self.latest() or 0) + 1
            final = os.path.join(self.state_dir, f"snap_{sid:08d}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)

            db = session.db
            db_arrays: Dict[str, np.ndarray] = {}
            relation_attrs = {}
            for rname, rel in db.relations.items():
                relation_attrs[rname] = list(rel.attrs)
                for attr, col in rel.columns.items():
                    db_arrays[f"rel__{rname}__{attr}"] = np.asarray(col)
            _write_npz(os.path.join(tmp, "db.npz"), db_arrays, self.fsync)

            # the mid-write barrier: db.npz exists, the rest does not —
            # the tmp dir must be ignored by restore
            chaos.crash_point("store.snapshot.mid_write")

            _write_npz(
                os.path.join(tmp, "dicts.npz"),
                {a: np.asarray(v) for a, v in db.dictionaries.items()},
                self.fsync,
            )

            bundles_meta = []
            for bi, b in enumerate(session.bundles):
                fname = f"bundle_{bi}.npz"
                arrays: Dict[str, np.ndarray] = {}
                monos = []
                for ti, (mono, (keys, vals)) in enumerate(
                    b.result.tables.items()
                ):
                    monos.append(_mono_to_json(mono))
                    arrays[f"t{ti}__vals"] = np.asarray(vals)
                    for attr, col in keys.items():
                        arrays[f"t{ti}__k__{attr}"] = np.asarray(col)
                _write_npz(os.path.join(tmp, fname), arrays, self.fsync)
                bundles_meta.append({
                    "file": fname,
                    "key": {
                        "features": list(b.key.features),
                        "response": b.key.response,
                        "degree": b.key.degree,
                        "squares": b.key.squares,
                        "fds": [[d, list(ds)] for d, ds in b.key.fds],
                        "fingerprint": b.key.fingerprint,
                    },
                    "fds": _fds_to_json(b.fds),
                    "monomials": monos,
                    "count": float(b.result.count),
                    "aggregate_seconds": float(b.aggregate_seconds),
                })

            tenants_meta = []
            if server is not None:
                t_arrays: Dict[str, np.ndarray] = {}
                for ti, t in enumerate(server.tenants.values()):
                    meta = {
                        "name": t.name,
                        "spec": _spec_to_json(t.spec),
                        "features": list(t.features),
                        "response": t.response,
                        "fds": _fds_to_json(t.fds),
                        "subscribed": t.subscribed,
                        "fitted_at_delta": int(t.fitted_at_delta),
                        "has_fit": t.last_fit is not None,
                    }
                    if t.last_fit is not None:
                        meta["loss"] = float(t.last_fit.loss)
                        params = t.last_fit.params
                        if isinstance(params, dict):  # FaMa {theta, V}
                            t_arrays[f"p{ti}__theta"] = np.asarray(
                                params["theta"]
                            )
                            # V is a dict: feature index -> (card, rank)
                            # factor matrix; one npz entry per factor
                            for vk, vmat in params["V"].items():
                                t_arrays[f"p{ti}__V__{int(vk)}"] = (
                                    np.asarray(vmat)
                                )
                        else:
                            t_arrays[f"p{ti}__theta"] = np.asarray(params)
                    tenants_meta.append(meta)
                _write_npz(
                    os.path.join(tmp, "tenants.npz"), t_arrays, self.fsync
                )

            manifest = {
                "format": _FORMAT,
                "snapshot_id": sid,
                "deltas_applied": int(session.stats.deltas_applied),
                "fingerprint": session.schema_fingerprint,
                "relation_attrs": relation_attrs,
                "adom": {a: int(v) for a, v in db.adom.items()},
                "wal": (
                    self._wal.position() if self._wal is not None
                    else {"watermark": 0, "applied_above": []}
                ),
                "bundles": bundles_meta,
                "tenants": tenants_meta,
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                if self.fsync:
                    os.fsync(f.fileno())
            if self.fsync:
                fsync_dir(tmp)

            chaos.crash_point("store.snapshot.pre_rename")
            os.rename(tmp, final)
            if self.fsync:
                fsync_dir(self.state_dir)
            chaos.crash_point("store.snapshot.post_rename_pre_truncate")

            # the snapshot is live: its watermark covers every applied
            # record, so the consumed WAL prefix can go
            if self._wal is not None:
                self.wal.truncate()

            for old in self._snapshot_ids()[: -self.keep]:
                shutil.rmtree(
                    os.path.join(self.state_dir, f"snap_{old:08d}"),
                    ignore_errors=True,
                )
                self.stats.snapshots_pruned += 1

        dt = time.monotonic() - t0
        self.stats.snapshots += 1
        self.stats.snapshot_seconds_last = dt
        self.stats.snapshot_seconds_total += dt
        self.stats.bundles_saved += len(bundles_meta)
        self.stats.tenants_saved += len(tenants_meta)
        obs.counter("acdc_store_snapshots").inc()
        return final

    # ------------------------------------------------------------------
    # restore
    # ------------------------------------------------------------------
    def restore_into(self, session: Session, server=None) -> RestoreReport:
        """Warm-restore the latest snapshot into a freshly constructed
        session (same schema/catalog, base data regenerated or reloaded
        any way the caller likes — every relation is replaced
        wholesale). With ``server``, the tenant registry and published
        params are rebuilt and unapplied WAL records re-enter the
        refresh queue (applied on the next drain, exactly as if they had
        been submitted moments before the crash)."""
        t0 = time.monotonic()
        with obs.span("ft.restore"):
            sid = self.latest()
            if sid is None:
                raise FileNotFoundError(
                    f"no committed snapshot under {self.state_dir}"
                )
            snap_dir = os.path.join(self.state_dir, f"snap_{sid:08d}")
            with open(os.path.join(snap_dir, "manifest.json")) as f:
                manifest = json.load(f)
            if manifest["format"] != _FORMAT:
                raise ValueError(
                    f"snapshot format {manifest['format']} != {_FORMAT}"
                )
            if manifest["fingerprint"] != session.schema_fingerprint:
                raise ValueError(
                    "snapshot schema fingerprint "
                    f"{manifest['fingerprint']!r} does not match the "
                    f"session's {session.schema_fingerprint!r} — restore "
                    "needs a session built over the same (catalog, query)"
                )
            missing = set(manifest["relation_attrs"]) ^ set(
                session.db.relations
            )
            if missing:
                raise ValueError(
                    f"snapshot/session relation mismatch: {sorted(missing)}"
                )

            db_z = np.load(os.path.join(snap_dir, "db.npz"))
            relations = {
                rname: {
                    attr: db_z[f"rel__{rname}__{attr}"] for attr in attrs
                }
                for rname, attrs in manifest["relation_attrs"].items()
            }
            dicts_z = np.load(
                os.path.join(snap_dir, "dicts.npz"), allow_pickle=True
            )
            dictionaries = {a: dicts_z[a] for a in dicts_z.files}
            session.install_restored(
                relations,
                adom={a: int(v) for a, v in manifest["adom"].items()},
                dictionaries=dictionaries,
                deltas_applied=manifest["deltas_applied"],
            )

            for bm in manifest["bundles"]:
                bz = np.load(os.path.join(snap_dir, bm["file"]))
                tables = {}
                for ti, mono_json in enumerate(bm["monomials"]):
                    keys = {
                        name[len(f"t{ti}__k__"):]: bz[name]
                        for name in bz.files
                        if name.startswith(f"t{ti}__k__")
                    }
                    tables[_mono_from_json(mono_json)] = (
                        keys, jnp.asarray(bz[f"t{ti}__vals"])
                    )
                km = bm["key"]
                key = BundleKey(
                    features=tuple(km["features"]),
                    response=km["response"],
                    degree=km["degree"],
                    squares=km["squares"],
                    fds=tuple((d, tuple(ds)) for d, ds in km["fds"]),
                    fingerprint=km["fingerprint"],
                )
                session.restore_bundle(
                    key,
                    tables,
                    count=bm["count"],
                    aggregate_seconds=bm["aggregate_seconds"],
                    fds=_fds_from_json(bm["fds"]),
                )
            self.stats.bundles_restored += len(manifest["bundles"])

            n_tenants = 0
            if server is not None and manifest["tenants"]:
                n_tenants = self._restore_tenants(
                    session, server, snap_dir, manifest["tenants"]
                )

            wal_pos = manifest["wal"]
            replayed = 0
            if server is not None:
                self.wal.set_position(
                    wal_pos["watermark"], wal_pos["applied_above"]
                )
                for seq, delta in self.wal.replay():
                    server.refresh.restore_entry(delta, seq)
                    replayed += 1
                self.stats.wal_records_requeued += replayed

        dt = time.monotonic() - t0
        self.stats.restores += 1
        self.stats.restore_seconds_last = dt
        obs.counter("acdc_store_restores").inc()
        return RestoreReport(
            snapshot_id=sid,
            deltas_applied=manifest["deltas_applied"],
            bundles=len(manifest["bundles"]),
            tenants=n_tenants,
            wal_replayed=replayed,
            seconds=dt,
        )

    def _restore_tenants(self, session: Session, server, snap_dir: str,
                         tenants_meta: list) -> int:
        from repro.serve.server import Tenant  # runtime: serve layers above ft

        params_z = np.load(os.path.join(snap_dir, "tenants.npz"))
        for ti, meta in enumerate(tenants_meta):
            spec = _spec_from_json(meta["spec"])
            features = tuple(meta["features"])
            fds = _fds_from_json(meta["fds"])
            key = (
                server.fingerprint, features, meta["response"],
                fd_key(fds), spec,
            )
            tenant = Tenant(
                name=meta["name"],
                key=key,
                spec=spec,
                features=features,
                response=meta["response"],
                fds=fds,
                subscribed=meta["subscribed"],
                fitted_at_delta=meta["fitted_at_delta"],
            )
            if meta["has_fit"]:
                # rebuild the predictable model around the restored
                # params; the bundle lookup is a subsumption hit off the
                # bundles restored above (no aggregate pass)
                model, _sig, wl, _bundle = session.materialize(
                    spec, features, meta["response"], fds
                )
                theta = jnp.asarray(params_z[f"p{ti}__theta"])
                v_prefix = f"p{ti}__V__"
                V = {
                    int(name[len(v_prefix):]): jnp.asarray(params_z[name])
                    for name in params_z.files
                    if name.startswith(v_prefix)
                }
                params = {"theta": theta, "V": V} if V else theta
                tenant.last_fit = FitResult(
                    spec=spec,
                    model=model,
                    params=params,
                    sigma=None,
                    workload=wl,
                    plan=None,
                    solver=SolverResult(
                        params=params, loss=float(meta["loss"]),
                        iterations=0, converged=True,
                    ),
                    bundle=None,
                    aggregate_seconds=0.0,
                    converge_seconds=0.0,
                )
            server.tenants[key] = tenant
        self.stats.tenants_restored += len(tenants_meta)
        return len(tenants_meta)
