"""Crash-safe write-ahead log for ``DeltaEvent`` streams (DESIGN.md §16).

The durability contract of the serving plane: a delta is acknowledged
only after its record is appended AND fsynced here — so an acked delta
survives any crash, and replay after restart re-queues exactly the
records a snapshot has not yet captured. The log is the cheap half of
ARIES-style recovery: snapshots (``ft.store``) bound its length, and
``truncate()`` unlinks fully-consumed segments after each snapshot
renames into place.

On-disk format — append-only segments ``wal_<firstseq:016d>.log``:

    MAGIC                                   b"ACDCWAL1\\n"
    frame := header | payload
    header := struct "<QII": seq (u64), payload length (u32), crc32 (u32)
    payload := np.savez of the delta's columns
               ("relation" 0-d str, "i__<attr>"/"d__<attr>" arrays)

Replay verifies length + CRC per frame. A bad frame in the *last*
segment is a torn tail — the record was mid-append at the crash, so it
was never acked and is legitimately discarded (and truncated away on
reopen, so later appends never land behind garbage). A bad frame in any
earlier segment is real corruption and raises ``CorruptWal``.

Applied-position tracking: ``mark_applied(seqs)`` advances a contiguous
``watermark`` (every seq ≤ it is applied) plus an ``applied_above`` set
for out-of-order applies; the pair is persisted in the snapshot manifest
so replay after restore skips exactly the records whose effects the
snapshot already contains — no acked delta lost, none applied twice.
"""

from __future__ import annotations

import dataclasses
import io
import os
import re
import struct
import threading
import zlib
from typing import Dict, Iterable, List, Optional, Set, Tuple

import numpy as np

from repro import obs
from repro.delta import Delta

from . import chaos

MAGIC = b"ACDCWAL1\n"
_HEADER = struct.Struct("<QII")     # seq, payload_len, crc32
_SEGMENT_RE = re.compile(r"^wal_(\d{16})\.log$")


class CorruptWal(RuntimeError):
    """A non-tail WAL frame failed its length/CRC check."""


def fsync_dir(path: str) -> None:
    """fsync a directory so renames/creates/unlinks inside it are
    durable — the half of atomic-rename most writers forget (the
    ``ckpt.checkpoint`` satellite fix of this PR does the same)."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _encode(delta: Delta) -> bytes:
    arrays: Dict[str, np.ndarray] = {"relation": np.array(delta.relation)}
    for prefix, cols in (("i", delta.inserts), ("d", delta.deletes)):
        for attr, v in cols.items():
            arrays[f"{prefix}__{attr}"] = np.asarray(v)
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    return buf.getvalue()


def _decode(payload: bytes) -> Delta:
    z = np.load(io.BytesIO(payload), allow_pickle=False)
    inserts: Dict[str, np.ndarray] = {}
    deletes: Dict[str, np.ndarray] = {}
    relation = ""
    for name in z.files:
        if name == "relation":
            relation = str(z[name][()])
        elif name.startswith("i__"):
            inserts[name[3:]] = z[name]
        elif name.startswith("d__"):
            deletes[name[3:]] = z[name]
    return Delta(relation, inserts=inserts, deletes=deletes)


@dataclasses.dataclass
class WalStats(obs.StatsBase):
    appends: int = 0
    bytes_appended: int = 0
    fsyncs: int = 0
    rotations: int = 0
    segments_truncated: int = 0     # segments unlinked by truncate()
    records_replayed: int = 0       # records yielded to a restore
    records_skipped: int = 0        # replay records below the watermark
    torn_tail_drops: int = 0        # partial tail frames discarded


class DeltaWAL:
    """Append-fsync-ack delta log with segment rotation and truncation."""

    def __init__(self, directory: str, rotate_bytes: int = 4 << 20,
                 fsync: bool = True):
        self.directory = directory
        self.rotate_bytes = rotate_bytes
        self.fsync = fsync
        self.stats = WalStats()     # lock: _mu
        self._mu = threading.Lock()
        self._fh = None             # lock: _mu — active segment handle
        self._active: Optional[str] = None  # lock: _mu — active segment path
        self._next_seq = 1          # lock: _mu
        self._watermark = 0         # lock: _mu — every seq <= it is applied
        self._applied: Set[int] = set()  # lock: _mu — applied above watermark
        os.makedirs(directory, exist_ok=True)
        self._recover_tail()

    # ------------------------------------------------------------------
    # open/scan
    # ------------------------------------------------------------------
    def _segment_paths(self) -> List[str]:
        names = sorted(
            n for n in os.listdir(self.directory) if _SEGMENT_RE.match(n)
        )
        return [os.path.join(self.directory, n) for n in names]

    @staticmethod
    def _scan_segment(path: str) -> Tuple[int, int]:
        """Return (valid byte length, max seq) of the segment's intact
        frame prefix; everything past it is a torn tail."""
        size = os.path.getsize(path)
        max_seq = 0
        with open(path, "rb") as f:
            if f.read(len(MAGIC)) != MAGIC:
                return 0, 0
            off = len(MAGIC)
            while True:
                header = f.read(_HEADER.size)
                if len(header) < _HEADER.size:
                    return off, max_seq
                seq, length, crc = _HEADER.unpack(header)
                if off + _HEADER.size + length > size:
                    return off, max_seq
                payload = f.read(length)
                if len(payload) < length or zlib.crc32(payload) != crc:
                    return off, max_seq
                off += _HEADER.size + length
                max_seq = max(max_seq, seq)

    def _recover_tail(self) -> None:  # lock: held(_mu) — __init__-time,
        # before the instance is visible to any other thread
        segments = self._segment_paths()
        max_seq = 0
        for i, path in enumerate(segments):
            valid, seg_max = self._scan_segment(path)
            size = os.path.getsize(path)
            if valid < size:
                if i != len(segments) - 1:
                    raise CorruptWal(
                        f"corrupt frame mid-log in {path} "
                        f"(valid prefix {valid} of {size} bytes)"
                    )
                # torn tail: the frame was mid-append at the crash and
                # was never acked — drop it so new appends are readable
                with open(path, "r+b") as f:
                    f.truncate(valid)
                self.stats.torn_tail_drops += 1
            max_seq = max(max_seq, seg_max)
        self._next_seq = max_seq + 1
        if segments:
            self._active = segments[-1]
            self._fh = open(self._active, "ab")
            if self._fh.tell() == 0:
                # the tail truncation emptied a segment whose MAGIC was
                # itself torn — re-stamp it before any append lands
                self._fh.write(MAGIC)
                self._fh.flush()
                if self.fsync:
                    os.fsync(self._fh.fileno())
        else:
            self._open_segment(self._next_seq)

    def _open_segment(self, first_seq: int) -> None:  # lock: held(_mu)
        path = os.path.join(self.directory, f"wal_{first_seq:016d}.log")
        self._fh = open(path, "ab")
        if self._fh.tell() == 0:
            self._fh.write(MAGIC)
            self._fh.flush()
            if self.fsync:
                os.fsync(self._fh.fileno())
        self._active = path
        chaos.crash_point("wal.rotate.pre_dirsync")
        if self.fsync:
            fsync_dir(self.directory)

    # ------------------------------------------------------------------
    # append (the ack barrier)
    # ------------------------------------------------------------------
    def append(self, delta: Delta) -> int:
        """Durably log one delta; returns its sequence number. The fsync
        happens BEFORE return — callers may ack as soon as this does."""
        payload = _encode(delta)
        header_and_payload_len = _HEADER.size + len(payload)
        with self._mu:
            seq = self._next_seq
            header = _HEADER.pack(seq, len(payload), zlib.crc32(payload))
            self._fh.write(header)
            self._fh.flush()
            # the torn-record barrier: header (or any prefix) on disk,
            # payload not — replay must discard this frame
            chaos.crash_point("wal.append.mid")
            self._fh.write(payload)
            self._fh.flush()
            chaos.crash_point("wal.append.pre_fsync")
            if self.fsync:
                os.fsync(self._fh.fileno())
                self.stats.fsyncs += 1
            self._next_seq = seq + 1
            self.stats.appends += 1
            self.stats.bytes_appended += header_and_payload_len
            if self._fh.tell() >= self.rotate_bytes:
                self._fh.close()
                self._open_segment(self._next_seq)
                self.stats.rotations += 1
        obs.counter("acdc_wal_appends").inc()
        return seq

    # ------------------------------------------------------------------
    # applied-position tracking
    # ------------------------------------------------------------------
    def mark_applied(self, seqs: Iterable[int]) -> None:
        """Record that the session state now reflects these records."""
        with self._mu:
            for s in seqs:
                if s > self._watermark:
                    self._applied.add(s)
            while (self._watermark + 1) in self._applied:
                self._watermark += 1
                self._applied.discard(self._watermark)

    @property
    def watermark(self) -> int:
        with self._mu:
            return self._watermark

    def position(self) -> dict:
        """The applied position, JSON-shaped for the snapshot manifest."""
        with self._mu:
            return {
                "watermark": self._watermark,
                "applied_above": sorted(self._applied),
            }

    def set_position(self, watermark: int,
                     applied_above: Iterable[int] = ()) -> None:
        """Adopt a manifest's applied position after a restore."""
        with self._mu:
            self._watermark = int(watermark)
            self._applied = {
                int(s) for s in applied_above if s > watermark
            }

    # ------------------------------------------------------------------
    # replay / truncate
    # ------------------------------------------------------------------
    def replay(self) -> List[Tuple[int, Delta]]:
        """Every durable record the current applied position does not
        cover, in sequence order — the restart re-queue set."""
        with self._mu:
            watermark, applied = self._watermark, set(self._applied)
            segments = self._segment_paths()
        out: List[Tuple[int, Delta]] = []
        skipped = 0
        for i, path in enumerate(segments):
            size = os.path.getsize(path)
            with open(path, "rb") as f:
                if f.read(len(MAGIC)) != MAGIC:
                    raise CorruptWal(f"bad magic in {path}")
                off = len(MAGIC)
                while off < size:
                    header = f.read(_HEADER.size)
                    seq = length = crc = None
                    payload = b""
                    if len(header) == _HEADER.size:
                        seq, length, crc = _HEADER.unpack(header)
                        payload = f.read(length)
                    if (
                        len(header) < _HEADER.size
                        or len(payload) < length
                        or zlib.crc32(payload) != crc
                    ):
                        if i == len(segments) - 1:
                            break   # torn tail: never acked, not replayed
                        raise CorruptWal(
                            f"corrupt frame at {path}:{off}"
                        )
                    off += _HEADER.size + length
                    if seq <= watermark or seq in applied:
                        skipped += 1
                        continue
                    out.append((seq, _decode(payload)))
        out.sort(key=lambda pair: pair[0])
        with self._mu:
            self.stats.records_replayed += len(out)
            self.stats.records_skipped += skipped
        return out

    def truncate(self) -> int:
        """Unlink segments the watermark has fully consumed (called after
        a snapshot commits). The active segment is rotated away first
        when it too is consumed, so a long-lived quiet server does not
        pin its whole history in one file."""
        with self._mu:
            if (
                self._active is not None
                and self._next_seq - 1 <= self._watermark
                and self._fh.tell() > len(MAGIC)
            ):
                self._fh.close()
                self._open_segment(self._next_seq)
                self.stats.rotations += 1
            segments = self._segment_paths()
            firsts = [
                int(_SEGMENT_RE.match(os.path.basename(p)).group(1))
                for p in segments
            ]
            removed = 0
            for i, path in enumerate(segments):
                if path == self._active:
                    continue
                # a segment is dead iff every record in it is <= the
                # watermark — true when the NEXT segment starts at or
                # below watermark+1
                if i + 1 < len(segments) and firsts[i + 1] <= self._watermark + 1:
                    os.unlink(path)
                    removed += 1
            if removed:
                self.stats.segments_truncated += removed
                if self.fsync:
                    fsync_dir(self.directory)
        return removed

    # ------------------------------------------------------------------
    def close(self) -> None:
        with self._mu:
            if self._fh is not None:
                self._fh.close()
                self._fh = None
