"""Deterministic fault injection for the durability plane (DESIGN.md §16).

Crash-consistency claims are only as good as the crashes they survive,
so the WAL, the snapshot writer, and the executor dispatch each pass
through a *named* site here:

    chaos.crash_point("store.snapshot.pre_rename")
    chaos.fault_point("executor.dispatch")

A site is inert (one dict lookup + one env check) unless armed. Two
arming mechanisms, both deterministic:

  * **in-process** — ``arm(site, action=...)``: the next ``count`` hits
    trip the site. ``action="raise"`` raises ``SimulatedCrash`` (a
    ``BaseException``, so no ``except Exception`` handler on the way up
    can swallow the "process died here" fiction); ``action="fault"``
    raises ``FaultInjected`` (a ``TransientError`` — the retryable
    kind); ``action="exit"`` calls ``os._exit(137)`` — a real SIGKILL-
    grade death for subprocess tests.
  * **cross-process** — ``ACDC_CRASH_POINT=<site>`` in the environment
    kills the process with ``os._exit(137)`` on the Nth hit of that
    crash site (``ACDC_CRASH_HITS``, default 1). This is how the CI
    recovery smoke murders a live ``acdc_serve`` at an exact barrier.

The crash matrix in ``tests/test_ft.py`` arms every named site in turn,
restarts from the state dir, and proves refit parity — the sites are the
contract, so add one next to every new durability barrier.

Named sites (keep in sync with DESIGN.md §16):

    wal.append.mid                    after the record header is on disk,
                                      before the payload (torn tail)
    wal.append.pre_fsync              full frame written, not yet fsynced
    wal.rotate.pre_dirsync            new segment created, dir not synced
    store.snapshot.mid_write          some snapshot files written, not all
    store.snapshot.pre_rename         tmp dir complete, rename pending
    store.snapshot.post_rename_pre_truncate
                                      snapshot live, WAL not yet truncated
    executor.dispatch                 fault site: transient executor error
"""

from __future__ import annotations

import os
import threading
from typing import Dict, Optional

from .resilience import TransientError


class SimulatedCrash(BaseException):
    """An armed crash site tripped. Deliberately NOT an ``Exception`` —
    the point of a simulated crash is that nothing on the unwind path
    gets to handle it and "keep going"."""

    def __init__(self, site: str):
        super().__init__(f"simulated crash at {site!r}")
        self.site = site


class FaultInjected(TransientError):
    """An armed fault site tripped: a retryable transient failure."""

    def __init__(self, site: str):
        super().__init__(f"injected transient fault at {site!r}")
        self.site = site


class _Arm:
    __slots__ = ("action", "remaining", "skip")

    def __init__(self, action: str, count: int, after: int):
        self.action = action
        self.remaining = count
        self.skip = after           # hits to let through before tripping


_mu = threading.Lock()
_armed: Dict[str, _Arm] = {}        # lock: _mu
_hits: Dict[str, int] = {}          # lock: _mu


def arm(site: str, action: str = "raise", count: int = 1,
        after: int = 0) -> None:
    """Arm ``site`` to trip on its next ``count`` hits (after letting
    ``after`` hits pass). Actions: ``raise`` -> SimulatedCrash,
    ``fault`` -> FaultInjected, ``exit`` -> os._exit(137)."""
    if action not in ("raise", "fault", "exit"):
        raise ValueError(f"unknown chaos action {action!r}")
    with _mu:
        _armed[site] = _Arm(action, count, after)


def disarm_all() -> None:
    """Reset every armed site and hit counter (test teardown)."""
    with _mu:
        _armed.clear()
        _hits.clear()


def hits(site: str) -> int:
    """How many times ``site`` has been passed through (armed or not)."""
    with _mu:
        return _hits.get(site, 0)


def _trip(site: str) -> Optional[str]:
    """Record a hit; return the armed action to take, if any."""
    with _mu:
        _hits[site] = _hits.get(site, 0) + 1
        a = _armed.get(site)
        if a is None:
            return None
        if a.skip > 0:
            a.skip -= 1
            return None
        if a.remaining <= 0:
            return None
        a.remaining -= 1
        if a.remaining <= 0:
            del _armed[site]
        return a.action


def _env_kill(site: str, env_var: str) -> None:
    if os.environ.get(env_var) != site:
        return
    threshold = int(os.environ.get("ACDC_CRASH_HITS", "1"))
    with _mu:
        n = _hits.get(site, 0)      # _trip already counted this hit
    if n >= threshold:
        os._exit(137)               # the SIGKILL fiction, made real


def crash_point(site: str) -> None:
    """A named crash barrier. Inert unless armed or selected by the
    ``ACDC_CRASH_POINT`` environment variable."""
    action = _trip(site)
    _env_kill(site, "ACDC_CRASH_POINT")
    if action is None:
        return
    if action == "exit":
        os._exit(137)
    if action == "fault":
        raise FaultInjected(site)
    raise SimulatedCrash(site)


def fault_point(site: str) -> None:
    """A named transient-fault site (retryable). Inert unless armed or
    selected by ``ACDC_FAULT_POINT``; ``arm(site, action="raise")``
    still escalates it to a crash when a test wants one."""
    action = _trip(site)
    if os.environ.get("ACDC_FAULT_POINT") == site:
        raise FaultInjected(site)
    if action is None:
        return
    if action == "exit":
        os._exit(137)
    if action == "raise":
        raise SimulatedCrash(site)
    raise FaultInjected(site)
