#!/usr/bin/env python
"""Crash-recovery smoke (CI tier1-recovery): SIGKILL acdc_serve
mid-stream, restart from the same --state-dir, assert warm recovery.

    PYTHONPATH=src python scripts/recovery_smoke.py [--schema snowflake]

Phase 1 launches ``repro.launch.indb_serve`` with the durability plane
on and a periodic snapshot cadence, waits until at least one snapshot
has committed (plus a little more served traffic), then delivers
SIGKILL — no atexit, no flush, the process is simply gone, exactly the
failure the WAL + atomic-snapshot protocol is built for.

Phase 2 restarts the server on the same state dir with the metrics
exporter up and asserts:

  * the "[serve] warm restore" line appears (snapshot found and loaded);
  * the run completes cleanly (exit 0) — leftover ``snap_*.tmp`` from a
    mid-snapshot kill is ignored, the WAL tail replays or is dropped;
  * ``GET /snapshot`` on the live exporter reports a healthy durability
    plane: ``durability.enabled`` and at least one restore counted.
"""

from __future__ import annotations

import argparse
import json
import os
import re
import signal
import subprocess
import sys
import tempfile
import time
import urllib.request

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SERVE = [sys.executable, "-u", "-m", "repro.launch.indb_serve"]


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return env


def _spawn(extra):
    return subprocess.Popen(
        SERVE + extra, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True, env=_env(), cwd=REPO,
    )


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--schema", default="snowflake")
    ap.add_argument("--n-requests", type=int, default=60)
    ap.add_argument("--scale", type=float, default=0.3)
    ap.add_argument("--timeout", type=float, default=420.0)
    args = ap.parse_args()

    state_dir = tempfile.mkdtemp(prefix="acdc_recovery_smoke_")
    base = [
        "--schema", args.schema, "--n-requests", str(args.n_requests),
        "--scale", str(args.scale), "--state-dir", state_dir,
    ]

    # ---- phase 1: serve, snapshot, then die without warning ----------
    print(f"[smoke] phase 1: serving with state dir {state_dir}")
    p1 = _spawn(base + ["--snapshot-every", "5"])
    deadline = time.time() + args.timeout
    snapshotted = served_after = 0
    try:
        for line in p1.stdout:
            print(f"  [victim] {line}", end="")
            if " snapshot " in line:
                snapshotted += 1
            elif snapshotted and re.search(r" (fit|predict) ", line):
                served_after += 1
            # kill once a snapshot committed AND more traffic was served
            # on top of it (so recovery has something to be stale about)
            if snapshotted and served_after >= 3:
                break
            if time.time() > deadline:
                p1.kill()
                sys.exit("[smoke] FAIL: no snapshot before timeout")
        else:
            sys.exit("[smoke] FAIL: victim finished before we could kill "
                     "it — raise --n-requests")
        os.kill(p1.pid, signal.SIGKILL)
    finally:
        p1.wait()
        p1.stdout.close()
    print(f"\n[smoke] SIGKILL delivered after {snapshotted} snapshot(s) "
          f"and {served_after} further request(s); exit {p1.returncode}")
    assert p1.returncode != 0, "SIGKILL'd process reported success?"

    # ---- phase 2: restart on the same state dir ----------------------
    print("[smoke] phase 2: restarting from the state dir")
    p2 = _spawn(base + ["--metrics-port", "0"])
    warm_line = url = None
    out = []
    try:
        for line in p2.stdout:
            print(f"  [restart] {line}", end="")
            out.append(line)
            if "warm restore" in line:
                warm_line = line.strip()
            m = re.search(r"exporter at (http://\S+)/metrics", line)
            if m:
                url = m.group(1)
            if warm_line and url:
                break
            if time.time() > deadline:
                p2.kill()
                sys.exit("[smoke] FAIL: no warm restore before timeout")
        if warm_line is None or url is None:
            p2.wait()
            sys.exit("[smoke] FAIL: restart produced no warm-restore or "
                     "exporter line:\n" + "".join(out))

        with urllib.request.urlopen(f"{url}/snapshot", timeout=30) as r:
            snap = json.load(r)
        dur = snap["durability"]
        assert dur["enabled"] is True, dur
        assert dur["store"]["restores"] >= 1, dur
        assert dur["store"]["snapshots"] >= 0, dur
        print(f"[smoke] /snapshot durability plane: {json.dumps(dur)}")

        for line in p2.stdout:       # drain to completion
            print(f"  [restart] {line}", end="")
    finally:
        rc = p2.wait()
        p2.stdout.close()
    if rc != 0:
        sys.exit(f"[smoke] FAIL: restarted server exited {rc}")

    print(f"[smoke] OK: {warm_line}")
    print("[smoke] OK: restart served the full trace and exited 0")
    return 0


if __name__ == "__main__":
    sys.exit(main())
