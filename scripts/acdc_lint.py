#!/usr/bin/env python
"""acdc-lint CLI: run the repo's invariant linter over files/trees.

    python scripts/acdc_lint.py src [tests benchmarks ...]

Exit status 1 when any diagnostic fires. Pure stdlib — runs without
jax, so CI lints before installing the accelerator stack. Rules and
the suppression syntax (`# acdc: ignore[ACDC00N]`) are documented in
``repro.check.lint.rules`` and DESIGN.md §13.
"""

from __future__ import annotations

import argparse
import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
_SRC = os.path.join(os.path.dirname(_HERE), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from repro.check.lint import lint_paths  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="acdc-lint", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("paths", nargs="+", help="files or directories to lint")
    ap.add_argument(
        "--rule", action="append", default=None,
        help="only report these rule ids (repeatable)",
    )
    args = ap.parse_args(argv)
    diags = lint_paths(args.paths)
    if args.rule:
        keep = set(args.rule)
        diags = [d for d in diags if d.rule in keep]
    for d in diags:
        print(d)
    n = len(diags)
    print(f"acdc-lint: {n} finding{'s' if n != 1 else ''} "
          f"in {len(args.paths)} path(s)")
    return 1 if diags else 0


if __name__ == "__main__":
    raise SystemExit(main())
