"""Regenerate the §Dry-run and §Roofline tables inside EXPERIMENTS.md from
artifacts/dryrun. Run after a dry-run matrix completes:

    PYTHONPATH=src python scripts/inject_tables.py
"""

import glob
import json
import re
import sys

sys.path.insert(0, "src")

from repro.launch.roofline import build_table, load_artifacts, terms  # noqa: E402


def dryrun_table() -> str:
    out = [
        "| arch | cell | mesh | compile s | strategy | micro | args GB/dev "
        "| temp GB/dev | collective mix |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    rows = []
    for f in sorted(glob.glob("artifacts/dryrun/*.json")):
        rows.append(json.load(open(f)))
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    rows.sort(key=lambda r: (r["arch"], order[r["cell"]], r["mesh"]))
    for r in rows:
        if not r["ok"]:
            out.append(f"| {r['arch']} | {r['cell']} | {r['mesh']} | FAILED {r['error']} |")
            continue
        tot = sum(r["collectives"].values()) or 1.0
        mix = " ".join(
            f"{k.replace('all-','a').replace('collective-permute','cp').replace('reduce-scatter','rs')}:{v/tot:.0%}"
            for k, v in sorted(r["collectives"].items(), key=lambda kv: -kv[1])[:3]
        )
        out.append(
            f"| {r['arch']} | {r['cell']} | {r['mesh']} | {r['compile_s']:.0f} "
            f"| {r.get('strategy','')} | {r.get('microbatches',1)} "
            f"| {r['argument_bytes']/1e9:.1f} | {r['temp_bytes']/1e9:.1f} | {mix} |"
        )
    return "\n".join(out)


def replace_between(text: str, marker: str, payload: str) -> str:
    # payload goes right after the marker line, replacing until a blank line
    # followed by '#' heading or end marker; simplest: marker line -> payload
    pattern = re.compile(
        rf"(<!-- {marker} -->)(.*?)(?=\n## |\n### |\Z)", re.S
    )
    return pattern.sub(lambda m: m.group(1) + "\n\n" + payload + "\n", text)


def main():
    md = open("EXPERIMENTS.md").read()
    md = replace_between(md, "DRYRUN_TABLE", dryrun_table())
    rows = load_artifacts("artifacts/dryrun", "pod1")
    md = replace_between(md, "ROOFLINE_TABLE", build_table(rows, 256))
    open("EXPERIMENTS.md", "w").write(md)
    print("tables injected:",
          len(glob.glob("artifacts/dryrun/*.json")), "artifacts")


if __name__ == "__main__":
    main()
